"""Benchmark harness: one function per paper table/figure plus kernel
micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig21]

Gated-tier results (names starting with ``kernel_`` or ``serving_``) are
persisted to ``BENCH_kernels.json`` at the repo root so the perf trajectory
is tracked across PRs; ``--check`` compares the fresh run against the
committed file first and **fails (exit 1) on a >20% regression** of any
headline number before overwriting it.  ``scripts/run_tests.sh --bench``
wraps ``--only kernel --check``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_kernels.json")

# Headline metrics gated by --check: (bench name, key, direction).
# "higher" must not drop below (1 - tol) x old; "lower" must not exceed
# (1 + tol) x old.  Raw *_us wall clocks are recorded but not gated (they
# are CPU-interpret-mode numbers and machine-dependent); the gated set is
# counts and exactness flags, which are stable run-to-run.
HEADLINE = [
    ("kernel_programmed", "bit_exact", "higher"),
    ("kernel_crossbar", "bit_exact", "higher"),
    ("kernel_crossbar", "adc_conversions", "lower"),
    ("kernel_zero_plane", "conversions_sparse", "lower"),
    ("kernel_zero_plane", "bit_exact", "higher"),
    ("kernel_repaired", "bit_exact", "higher"),
    ("kernel_repaired", "bit_exact_zero_fault", "higher"),
    ("kernel_repaired", "recovery_frac", "higher"),
    ("kernel_artifact_store", "bit_exact", "higher"),
    ("kernel_moe_programmed", "bit_exact", "higher"),
    ("kernel_sharded_programmed", "bit_exact", "higher"),
    ("kernel_lifecycle", "aged_monotone", "higher"),
    ("kernel_lifecycle", "comp_recovery_frac", "higher"),
    ("kernel_lifecycle", "refresh_bit_exact", "higher"),
    ("kernel_planned", "bit_exact", "higher"),
    ("kernel_planned", "conversions_ratio_max", "lower"),
    ("kernel_planned", "energy_ratio_max", "lower"),
    # serving traffic tier: latency is in decode *ticks* (deterministic —
    # one tick = one jitted decode step), so it gates like a count
    ("serving_traffic", "bit_exact", "higher"),
    ("serving_traffic", "p99_ticks", "lower"),
    ("serving_traffic", "p50_ticks", "lower"),
    ("serving_traffic", "tokens_per_tick", "higher"),
    ("serving_traffic", "farm_speedup_x", "higher"),
]
REGRESSION_TOL = 0.20

# Wall-clock-derived ratios are gated against fixed acceptance floors, not
# the last committed value — a noisy-box run that wrote an unusually high
# (or low) baseline must not make later honest runs fail (or let real
# regressions pass).  speedup_x >= 5 is this repo's program-once bar — the
# repaired path and the per-expert MoE path are held to the same floor, so
# neither a spare-column gather nor per-expert slicing can silently move
# programming-pipeline work into the steady state.  restore_speedup_x >= 2
# guards the serving-restart path: restoring a persisted chip must beat
# reprogramming it (in practice by orders of magnitude).
ABSOLUTE_FLOORS = {
    ("kernel_programmed", "speedup_x"): 5.0,
    ("kernel_repaired", "speedup_x"): 5.0,
    ("kernel_moe_programmed", "speedup_x"): 5.0,
    ("kernel_sharded_programmed", "speedup_x"): 5.0,
    ("kernel_artifact_store", "restore_speedup_x"): 2.0,
    # lifecycle acceptance (ISSUE 7): a refreshed chip must return to bit
    # identity exactly, and the free digital compensation must recover at
    # least half the drift-accrued error with zero reprogramming
    ("kernel_lifecycle", "refresh_bit_exact"): 1.0,
    ("kernel_lifecycle", "comp_recovery_frac"): 0.5,
    ("kernel_lifecycle", "aged_monotone"): 1.0,
    # repair acceptance (ISSUE 8): per-physical-crossbar repair with a
    # self-fault-discounted spare pool must recover >= 97% of the stuck-at
    # MSE at p = 1e-2 on the deep (K = 512) slab — the bench regime where
    # whole-column sparing structurally capped out at ~54%
    ("kernel_repaired", "recovery_frac"): 0.97,
    # planned-chip acceptance (ISSUE 8): the heterogeneous compile must be
    # bit-exact vs the homogeneous programmed path (ceilings below gate the
    # strict predicted-cost win)
    ("kernel_planned", "bit_exact"): 1.0,
    # serving-tier acceptance (ISSUE 10): the continuous-batching scheduler
    # must serve token-identical outputs to the slot-loop engine for the
    # same (seed, admission order); every request of the Poisson mix must
    # complete; tokens/tick is the batching-efficiency floor (measured 3.0
    # on the short/long mix); a 2-replica farm must beat 1 replica by
    # >= 1.3x on drain ticks (measured ~1.67x)
    ("serving_traffic", "bit_exact"): 1.0,
    ("serving_traffic", "n_completed"): 12.0,
    ("serving_traffic", "tokens_per_tick"): 2.0,
    ("serving_traffic", "farm_speedup_x"): 1.3,
}

# Ratio metrics where *small* is the win are gated against fixed acceptance
# ceilings: the planner's compile must predict strictly fewer conversions /
# less energy than the homogeneous baseline on every tested model (a ratio
# of 1.0 means it never found a better datapath — a planner regression even
# though nothing "slowed down")
ABSOLUTE_CEILINGS = {
    ("kernel_planned", "conversions_ratio_max"): 0.999,
    ("kernel_planned", "energy_ratio_max"): 0.999,
    # serving-tier latency ceiling: p99 is in deterministic decode ticks
    # (measured 18 on the pinned short/long mix) — a scheduler regression
    # that stalls admission or preempts spuriously blows through this long
    # before any wall clock would notice
    ("serving_traffic", "p99_ticks"): 24.0,
}


def check_regressions(old: dict, new: dict) -> list:
    """Compare headline numbers; return a list of human-readable failures."""
    failures = []
    for (bench, key), floor in ABSOLUTE_FLOORS.items():
        if bench in new and key in new[bench] and float(new[bench][key]) < floor:
            failures.append(
                f"{bench}.{key}: {float(new[bench][key]):.4g} < acceptance floor {floor}"
            )
    for (bench, key), ceil in ABSOLUTE_CEILINGS.items():
        if bench in new and key in new[bench] and float(new[bench][key]) > ceil:
            failures.append(
                f"{bench}.{key}: {float(new[bench][key]):.4g} > acceptance ceiling {ceil}"
            )
    for bench, key, direction in HEADLINE:
        if bench not in old or key not in old.get(bench, {}):
            continue  # metric is new — nothing to regress against
        if bench not in new:
            continue  # bench filtered out of this run (--only): not gated
        if key not in new[bench]:
            failures.append(f"{bench}.{key}: missing from fresh run")
            continue
        o, n = float(old[bench][key]), float(new[bench][key])
        if direction == "higher" and n < o * (1.0 - REGRESSION_TOL):
            failures.append(
                f"{bench}.{key}: {n:.4g} < {o:.4g} - {REGRESSION_TOL:.0%} (higher is better)"
            )
        elif direction == "lower" and n > o * (1.0 + REGRESSION_TOL):
            failures.append(
                f"{bench}.{key}: {n:.4g} > {o:.4g} + {REGRESSION_TOL:.0%} (lower is better)"
            )
    return failures


def main() -> None:
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    from benchmarks import kernel_bench, noise_sweep, paper_figures, serving_traffic

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument(
        "--json",
        default=BENCH_JSON,
        help=f"where to persist kernel-tier results (default {BENCH_JSON})",
    )
    ap.add_argument(
        "--no-json", action="store_true", help="skip writing the kernel JSON"
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="fail on >20%% regression of headline numbers vs the existing JSON",
    )
    args = ap.parse_args()

    kernel_results = {}
    print("name,us_per_call,derived")
    for name, fn in (
        paper_figures.ALL + kernel_bench.ALL + noise_sweep.ALL + serving_traffic.ALL
    ):
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        derived = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        compact = json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                              for k, v in derived.items()})
        print(f"{name},{dt_us:.0f},{compact}")
        # the gated tiers: kernel micro-benches + the serving traffic tier
        # both persist to BENCH_kernels.json (one trajectory file)
        if name.startswith(("kernel_", "serving_")):
            kernel_results[name] = {
                k: (round(float(v), 6) if isinstance(v, float) else v)
                for k, v in derived.items()
            }

    if not kernel_results or args.no_json:
        return

    old_kernels = {}
    if os.path.exists(args.json):
        with open(args.json) as f:
            old = json.load(f)
        old_kernels = old.get("kernels", old)

    if args.check and old_kernels:
        failures = check_regressions(old_kernels, kernel_results)
        if failures:
            print("PERF REGRESSION (>20% on headline numbers):", file=sys.stderr)
            for f_ in failures:
                print(f"  {f_}", file=sys.stderr)
            print(f"  (kept existing {args.json})", file=sys.stderr)
            sys.exit(1)
        print("perf check passed: no headline regression > 20%")

    # merge, don't replace: a filtered run (--only kernel_zero) must not
    # drop the other benches' baselines and silently disarm their gates
    merged = dict(old_kernels)
    merged.update(kernel_results)
    payload = {"schema": 1, "kernels": merged}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
