"""Quickstart: the Newton crossbar datapath in five minutes.

Runs the paper's core pipeline end to end on CPU:
  1. a bit-exact crossbar VMM (16-bit operands, 2-bit cells, 1-bit DAC,
     9-bit column ADCs) vs the integer-matmul oracle,
  2. the adaptive-ADC schedule (Fig 5) and its zero-accuracy-impact claim,
  3. Karatsuba & Strassen divide-and-conquer, bit-identical with fewer
     ADC conversions,
  4. the Pallas TPU kernel (interpret mode) matching everything above,
  5. the analytic Newton-vs-ISAAC headline numbers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import adc, crossbar as cb, karatsuba as ka, strassen as st
from repro.core import arch, energy as en, workloads as wl
from repro.kernels import ops

rng = np.random.default_rng(0)
x = rng.integers(0, 1 << 16, size=(4, 256))        # unsigned activations
w = rng.integers(-(1 << 15), 1 << 15, size=(256, 32))  # signed weights

print("== 1. crossbar datapath ==")
y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w)))
ref = cb.exact_vmm_reference(x, w, cb.DEFAULT_SPEC)
print(f"bit-exact vs int64 oracle: {np.array_equal(y, ref)}")

print("\n== 2. adaptive ADC (T2) ==")
sched = adc.adaptive_schedule(cb.DEFAULT_SPEC.replace(signed_weights=False))
print(f"SAR bit decisions: {sched.mean():.2f} avg of 9 "
      f"({100 * (1 - sched.mean() / 9):.0f}% fewer)")
tr = adc.make_partial_transform(cb.DEFAULT_SPEC, adc.SAFE_ADAPTIVE)
y_ad = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), partial_transform=tr))
print(f"adaptive output == full-resolution output: {np.array_equal(y_ad, ref)}")

print("\n== 3. divide & conquer (T3, T4) ==")
y_ka = np.asarray(ka.karatsuba_vmm(jnp.asarray(x), jnp.asarray(w)))
c1 = ka.karatsuba_cost(1)
print(f"karatsuba bit-exact: {np.array_equal(y_ka, ref)}; "
      f"ADC slots 128 -> {c1.adc_slots} (-{100*c1.adc_reduction_vs_baseline:.0f}%)")
y_st = np.asarray(st.strassen_matmul(jnp.asarray(x), jnp.asarray(w)))
print(f"strassen bit-exact: {np.array_equal(y_st, ref)} (7/8 of the products)")

print("\n== 4. Pallas kernel (interpret mode on CPU) ==")
y_k = np.asarray(ops.crossbar_vmm_op(jnp.asarray(x), jnp.asarray(w), interpret=True))
print(f"pallas == reference datapath: {np.array_equal(y_k, ref)}")

print("\n== 5. Newton vs ISAAC (paper Table II suite) ==")
res = en.evaluate_suite(wl.benchmark_suite())
h = en.headline(res)
print(f"power decrease:      {100*h['power_decrease']:.0f}%  (paper: 77%)")
print(f"energy decrease:     {100*h['energy_decrease']:.0f}%  (paper: 51%)")
print(f"throughput/area:     {h['throughput_per_area_x']:.2f}x (paper: 2.2x)")
pj_i = np.mean([r['isaac'].pj_per_op for r in res.values()])
pj_n = np.mean([r['newton (+strassen)'].pj_per_op for r in res.values()])
print(f"energy/op:           {pj_i:.2f} -> {pj_n:.2f} pJ (paper: 1.8 -> 0.85; ideal 0.33)")
