"""End-to-end training driver (deliverable b): train a ~100M-class model for
a few hundred steps with checkpoints, NaN-guards and deterministic resume.

Default trains a width-reduced smollm for 300 steps on synthetic data; pass
--full-360m to train the real 360M config (slow on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [])

from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-360m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "train",
        "--arch", "smollm-360m",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
    ]
    if not args.full_360m:
        argv.insert(2, "--reduced")
    sys.argv = argv
    train_mod.main()


if __name__ == "__main__":
    main()
