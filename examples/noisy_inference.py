"""Inference through realistic memristor devices: the device/ subsystem demo.

Three stages:
  1. write-verify calibration of one projection's weight slab — how many
     programming pulses it takes, what residual error is left, what faults do;
  2. layer-level accuracy vs conductance-variation sigma, full vs adaptive
     ADC — the curves ``benchmarks/noise_sweep.py`` produces in JSON form;
  3. a full (reduced) LM forward pass with every projection on the noisy
     crossbar datapath via ``CrossbarMode(device=...)``.

Run:  PYTHONPATH=src python examples/noisy_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced
from repro.core import adc
from repro.core import crossbar as cb
from repro.device import DeviceConfig, write_verify
from repro.kernels import ops
from repro.models import model as M
from repro.models.layers import CrossbarMode, crossbar_mode

rng = np.random.default_rng(0)
K, N = 256, 64
spec = cb.layer_scaled_spec(cb.DEFAULT_SPEC, K)
w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(K, N)))
wb = w.astype(jnp.int32) + spec.weight_bias

print("== 1. write-verify programming (sigma=0.2, 0.2% stuck cells) ==")
cfg = DeviceConfig(sigma=0.2, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8)
_, rep = write_verify(wb, spec, cfg)
print(f"pulses used {rep.iterations}; converged {100*rep.converged_frac:.2f}% "
      f"(stuck {100*rep.stuck_frac:.2f}%)")
print("mean |error| per pulse (cell codes): "
      + " -> ".join(f"{e:.3f}" for e in rep.per_iter_mean_error))

print("\n== 2. output error vs sigma, full vs SAFE_ADAPTIVE ADC ==")
x = jnp.asarray(rng.integers(0, 1 << 16, size=(8, K)))
y_ideal = np.asarray(cb.crossbar_vmm(x, w, spec), dtype=np.int64)
print(f"{'sigma':>6s} {'full rmse':>10s} {'adaptive rmse':>14s}")
for sigma in (0.0, 0.05, 0.1, 0.2):
    dev = DeviceConfig(sigma=sigma, write_verify_iters=4)
    from repro.device import effective_cell_codes

    g_eff = effective_cell_codes(wb, spec, dev)
    rmses = []
    for acfg in (None, adc.SAFE_ADAPTIVE):
        y = np.asarray(ops.noisy_vmm_op(x, g_eff, spec, adc_cfg=acfg), dtype=np.int64)
        rmses.append(float(np.sqrt(np.mean((y - y_ideal) ** 2.0))))
    tag = "  (bit-exact)" if sigma == 0.0 and rmses[0] == 0.0 else ""
    print(f"{sigma:6.2f} {rmses[0]:10.3f} {rmses[1]:14.3f}{tag}")

print("\n== 3. reduced LM forward on noisy crossbars (programmed once) ==")
# Bit-sliced W16 is brutally noise-sensitive: an MSB-slice cell holds bits
# 14-15, so conductance variation there perturbs the weight in proportion to
# *full scale*, not the weight's own magnitude (Xiao et al. 2021).  Even
# sigma=0.05 destroys the logits — which is what motivates the ROADMAP items
# on noise-aware training and fault-aware mapping.
#
# Each device config is compiled into programmed artifacts *once*
# (``program_model``) and the forward serves steady-state from that fixed
# chip — self-consistent noise across the run, no per-call reprogramming.
from repro.device import program_model

cfg_lm = reduced(configs.get_config("smollm-360m"))
params, _ = M.init_model(jax.random.PRNGKey(0), cfg_lm)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg_lm.vocab_size)
logits_f = M.forward(params, cfg_lm, tokens)
for label, dev in (
    ("ideal devices", None),
    ("sigma=0.02 + write-verify", DeviceConfig(sigma=0.02, write_verify_iters=6)),
    ("sigma=0.10 + write-verify", DeviceConfig(sigma=0.10, write_verify_iters=6)),
):
    prog = program_model(params, device=dev)
    with crossbar_mode(CrossbarMode(enabled=True, device=dev, programmed=prog)):
        logits_x = M.forward(params, cfg_lm, tokens)
    rel = float(jnp.linalg.norm(logits_x - logits_f) / jnp.linalg.norm(logits_f))
    agree = float(jnp.mean(jnp.argmax(logits_x, -1) == jnp.argmax(logits_f, -1)))
    print(f"{label:26s} relative error {rel:.2e}; argmax agreement {100*agree:.1f}%")
