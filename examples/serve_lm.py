"""Batched serving with continuous batching (deliverable b, serving kind).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve as serve_mod


def main():
    sys.argv = [
        "serve", "--arch", "smollm-360m", "--reduced",
        "--requests", "6", "--max-new", "12", "--max-batch", "3",
    ]
    serve_mod.main()


if __name__ == "__main__":
    main()
