"""Serve an LM with every projection on the Newton crossbar datapath.

Demonstrates the paper's technique as a first-class framework feature:
``CrossbarMode`` reroutes all linear layers through the bit-sliced W16A16
analog pipeline (Pallas kernel; interpret mode on CPU), and the analytic
model reports the Newton-vs-ISAAC energy for serving this architecture —
realizing the paper's §VI claim that the techniques extend to RNN/LSTM-class
(here: transformer) models.

Run:  PYTHONPATH=src python examples/crossbar_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import reduced
from repro.core import arch as hw, energy as en, workloads as wl
from repro.models import model as M
from repro.models.layers import CrossbarMode, crossbar_mode

cfg = reduced(configs.get_config("smollm-360m"))
params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)

print("== logits fidelity: crossbar datapath vs float ==")
logits_f = M.forward(params, cfg, tokens)
t0 = time.perf_counter()
with crossbar_mode(CrossbarMode(enabled=True)):
    logits_x = M.forward(params, cfg, tokens)
dt = time.perf_counter() - t0
rel = float(jnp.linalg.norm(logits_x - logits_f) / jnp.linalg.norm(logits_f))
agree = float(jnp.mean((jnp.argmax(logits_x, -1) == jnp.argmax(logits_f, -1))))
print(f"relative error {rel:.2e}; argmax agreement {100*agree:.1f}%  ({dt:.1f}s interpret mode)")

print("\n== Newton serving-energy estimate for every assigned arch ==")
# LM decode is an all-VMM workload with no off-critical-path FC phase, so
# the right Newton configuration keeps full-rate ADC tiles (the slow FC
# tiles exist for CNNs where the classifier runs once per image).
newton_lm_chip = hw.newton_chip(fc_tiles=False)
print(f"{'arch':22s} {'pJ/MAC newton':>14s} {'pJ/MAC isaac':>13s} {'ratio':>6s}")
for name in configs.ALL_ARCHS:
    full = configs.get_config(name)
    net = wl.lm_workload(full)
    newton = en.evaluate(net, newton_lm_chip, policy="newton", strassen=False)
    isaac = en.evaluate(net, hw.ISAAC_CHIP, policy="isaac")
    print(f"{name:22s} {newton.pj_per_op:14.2f} {isaac.pj_per_op:13.2f} "
          f"{isaac.energy_per_sample_j/newton.energy_per_sample_j:6.2f}x")
