"""Program-once crossbar serving: the programming-time / inference-time split.

Newton's premise is that weights are written into crossbars once and then
serve in-situ traffic indefinitely.  This demo shows the split end to end:

  1. layer level — compile one weight slab into a ``ProgrammedLinear``
     (paying fault draw + write-verify + IR drop + scale reductions once),
     then serve steady-state calls that are bit-identical to the old
     program-every-call path but many times faster;
  2. activity skipping — post-ReLU inputs leave most bit-planes dead; the
     kernels' zero-plane early-out never converts them, and the energy
     model's activity term prices the savings;
  3. model level — ``program_model`` + ``ServingEngine(crossbar=...)``:
     one fixed noisy chip serves a whole generation run.

Run:  PYTHONPATH=src python examples/programmed_serving.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import reduced
from repro.core import crossbar as cb
from repro.core import energy as E
from repro.core.arch import ISAAC_CHIP
from repro.core.workloads import alexnet
from repro.device import DeviceConfig, program_layer, programmed_matmul
from repro.kernels import ops
from repro.models import model as M
from repro.models.layers import CrossbarMode
from repro.serving.engine import ServingEngine

rng = np.random.default_rng(0)

print("== 1. program once, serve steady-state ==")
B, K, N = 8, 512, 256
x = jnp.asarray(np.abs(rng.normal(size=(B, K))).astype(np.float32))
w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
dev = DeviceConfig(sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8)


def timed(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


t_percall = timed(lambda: ops.crossbar_matmul(x, w, device=dev, interpret=True))
t0 = time.perf_counter()
art = program_layer(w, device=dev, with_report=True)
t_program = (time.perf_counter() - t0) * 1e3
t_steady = timed(lambda: programmed_matmul(x, art, interpret=True))
same = bool(jnp.array_equal(
    ops.crossbar_matmul(x, w, device=dev, interpret=True),
    programmed_matmul(x, art, interpret=True),
))
print(f"per-call (reprograms every time): {t_percall:8.1f} ms")
print(f"program once:                     {t_program:8.1f} ms "
      f"({art.report.iterations} write-verify pulses, "
      f"{100 * art.report.converged_frac:.1f}% converged)")
print(f"steady-state call:                {t_steady:8.1f} ms  "
      f"-> {t_percall / t_steady:.1f}x faster, bit-identical: {same}")

print("\n== 2. zero-plane skipping on post-ReLU inputs ==")
x_relu = jnp.asarray(
    (rng.integers(0, 1 << 9, size=(B, K)) * (rng.random((B, K)) < 0.3)).astype(np.int64)
)
stats = cb.conversion_stats(B, K, N, cb.DEFAULT_SPEC, x_codes=x_relu)
total = stats.conversions + stats.skipped_conversions
activity = stats.conversions / total
print(f"ADC conversions: {stats.conversions} issued, {stats.skipped_conversions} "
      f"skipped ({100 * (1 - activity):.0f}% of planes dead)")
r_dense = E.evaluate(alexnet(), ISAAC_CHIP)
r_act = E.evaluate(alexnet(), ISAAC_CHIP, activity=activity)
print(f"alexnet energy/sample at this activity: {r_act.energy_per_sample_j * 1e3:.2f} mJ "
      f"vs dense {r_dense.energy_per_sample_j * 1e3:.2f} mJ "
      f"({100 * (1 - r_act.energy_per_sample_j / r_dense.energy_per_sample_j):.0f}% saved)")

print("\n== 3. serving a reduced LM from one programmed chip ==")
cfg = reduced(configs.get_config("smollm-360m"))
params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
t0 = time.perf_counter()
eng = ServingEngine(
    cfg, params, max_batch=2, max_seq=64,
    crossbar=CrossbarMode(enabled=True, device=DeviceConfig(sigma=0.02, write_verify_iters=4)),
)
print(f"programmed {eng.crossbar.programmed.n_compiled} projection slabs once "
      f"in {time.perf_counter() - t0:.1f}s (deploy-time cost)")
eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=4)
t0 = time.perf_counter()
done = eng.run_until_done()
print(f"generated {done[0].generated} in {time.perf_counter() - t0:.1f}s — every "
      f"token served by the same fixed noisy chip, no reprogramming")

print("\n== 4. persist the chip: restart restores, never reprograms ==")
import tempfile

with tempfile.TemporaryDirectory() as ckpt_dir:
    eng.save_artifacts(ckpt_dir)
    t0 = time.perf_counter()
    eng2 = ServingEngine(
        cfg, params, max_batch=2, max_seq=64,
        crossbar=CrossbarMode(enabled=True, device=DeviceConfig(sigma=0.02, write_verify_iters=4)),
        restore_artifacts=ckpt_dir,
    )
    t_restore = time.perf_counter() - t0
    eng2.submit(np.array([1, 2, 3], np.int32), max_new_tokens=4)
    done2 = eng2.run_until_done()
    same_chip = all(
        bool(jnp.array_equal(a.g_eff, eng2.crossbar.programmed.by_name[n].g_eff))
        for n, a in eng.crossbar.programmed.by_name.items() if a.g_eff is not None
    )
    print(f"restored {eng2.crossbar.programmed.n_compiled} artifacts in "
          f"{t_restore:.2f}s (vs write-verify reprogramming); same chip "
          f"bit-for-bit: {same_chip}; generated {done2[0].generated} "
          f"(identical: {done2[0].generated == done[0].generated})")
